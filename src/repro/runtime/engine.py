"""Sharded, microbatched serving engine for compiled DA designs.

The deployment model of the paper (and hls4ml): a design is compiled
once, then serves inference at fixed microsecond-scale latency.  This
engine is the software analogue of the always-ready FPGA datapath — a
multi-model registry where each registered ``CompiledDesign`` (in-memory
or cold-started from a ``save_design`` artifact) gets:

  * N dispatch *shards* (``ServeConfig.shards``), each a bounded request
    queue + dispatcher thread + preallocated payload slab; ``submit``
    places requests round-robin across shards, ``submit_batch`` spreads
    contiguous chunks, and the per-model ``queue_depth`` backpressure
    budget is divided across shards;
  * a payload **slab** per shard: submitters write samples straight into
    a preallocated ring of slots and dispatchers gather whole batches
    out of it with one vectorized copy into a bucket-shaped scratch
    array — no per-request array allocations or per-request copies on
    the dispatch path;
  * microbatch formation per shard — at most ``max_batch`` requests,
    waiting at most ``max_wait_us`` after the first — with bucketed
    batch shapes (powers of two up to ``max_batch``) so the jitted
    integer forward pass (shared by all shards) compiles once per
    bucket and every batch is padded to the next bucket;
  * per-request latency accounting (submit -> result, p50/p95/p99,
    throughput) plus per-stage accounting (queue wait / batch-form /
    pad / dispatch / copy-out) and per-shard counters, merged across
    shards in ``stats()``.

Requests are single samples on the integer input grid (``in_shape``,
as ``CompiledDesign.forward_int`` consumes them); ``submit`` returns a
``concurrent.futures.Future`` resolving to the integer output.

Shutdown discipline: every Future handed out is resolved — with a
result while draining, or with :class:`EngineClosedError` once the
model is closed.  The closed flag is checked *under the shard lock* on
every enqueue, so a ``submit`` that grabbed a runner reference just
before ``unregister``/``shutdown`` popped it either lands in the queue
before the dispatcher's final drain (and is served) or observes the
flag and fails fast — the put-after-final-sweep window that used to
hang futures cannot occur.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from concurrent.futures import Future
from pathlib import Path

import jax
import numpy as np

from ..flow.config import UNSET, ServeConfig, resolve_legacy
from ..nn.compiler import CompiledDesign
from ..obs import trace
from ..obs.flight import FlightRecorder
from ..obs.metrics import Histogram, get_registry, render_prometheus
from .artifact import load_design
from .metrics import LatencyRecorder, StageAccumulator


def _serve_config_from_legacy(legacy: dict) -> ServeConfig:
    if "overflow" in legacy:
        legacy["backpressure"] = legacy.pop("overflow")
    if legacy.get("buckets") is not None:
        legacy["buckets"] = tuple(legacy["buckets"])
    return ServeConfig(**legacy)


class QueueFullError(RuntimeError):
    """Raised by ``submit`` when overflow policy is "reject" and the
    model's request queue is at capacity."""


class EngineClosedError(RuntimeError):
    """Raised by ``submit`` (or set on a Future) when the request raced
    ``unregister``/``shutdown``: the model's dispatchers are stopping or
    gone, so the request is failed fast instead of queued forever."""


class _Request:
    __slots__ = ("slot", "t_submit", "future", "tid")

    def __init__(self, slot: int, t_submit: float, future: Future, tid: int = 0):
        self.slot = slot
        self.t_submit = t_submit
        self.future = future
        self.tid = tid  # per-shard trace id, stamped at enqueue


def _default_buckets(max_batch: int) -> tuple[int, ...]:
    out = [1]
    while out[-1] < max_batch:
        out.append(min(out[-1] * 2, max_batch))
    return tuple(out)


class _Shard(threading.Thread):
    """One dispatch lane of a model: bounded request deque + payload
    slab + dispatcher thread.

    All shard state (deque, free-slot stack, counters) is guarded by one
    lock; submitters copy their sample into a reserved slab slot while
    holding it (the samples are small — the copy is cheaper than a
    second lock round-trip), and the dispatcher drains a whole batch in
    a single lock acquisition, then gathers the batch out of the slab
    with one vectorized copy into a per-bucket scratch array.
    """

    def __init__(self, runner: "_ModelRunner", idx: int, depth: int):
        super().__init__(
            daemon=True, name=f"da4ml-serve-{runner.model_name}-s{idx}"
        )
        self.runner = runner
        self.idx = idx
        self.depth = depth
        self.max_batch = runner.max_batch
        self.max_wait_s = runner.max_wait_s
        self.in_shape = runner.in_shape
        self._fn = runner._fn
        self._closed = runner._closed  # runner-wide: set first in stop()

        # payload slab: depth queued + max_batch executing slots can be
        # live at once; slots are recycled through a free-list stack
        cap = depth + runner.max_batch
        self.slab = np.empty((cap, *self.in_shape), np.int32)
        self._free: list[int] = list(range(cap))
        self._pending: deque[_Request] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        # bucket-shaped scratch: the gather target, reused every batch
        # (safe: the jitted call's result is materialized before reuse)
        self._scratch = {
            b: np.zeros((b, *self.in_shape), np.int32) for b in runner.buckets
        }

        self.metrics = LatencyRecorder()
        self.stage = StageAccumulator()
        # observability (single writer: this dispatcher thread) — per-stage
        # µs histograms and the per-request flight recorder; trace ids are
        # stamped at enqueue under the shard lock (shard idx in high bits
        # keeps them unique across shards)
        self.stage_hist = {s: Histogram() for s in StageAccumulator.STAGES}
        self.flight = FlightRecorder(capacity=2048, slow_k=16)
        self._tid_seq = itertools.count()
        self._tid_base = idx << 40
        self.n_batches = 0
        self.n_rejected = 0  # guarded by self._lock (shared with submitters)
        self._occupancy_sum = 0.0
        self.bucket_hits: dict[int, int] = {b: 0 for b in runner.buckets}
        self._stop = threading.Event()
        self._drained = threading.Event()

    # -- enqueue (submitter threads) -----------------------------------
    def _closed_error(self) -> EngineClosedError:
        return EngineClosedError(
            f"model {self.runner.model_name!r}: engine shut down"
        )

    def _full_error(self) -> QueueFullError:
        return QueueFullError(
            f"queue for model {self.runner.model_name!r} is full "
            f"({self.depth} requests on shard {self.idx})"
        )

    def put_one(self, x: np.ndarray, t_submit: float, block: bool) -> Future:
        fut: Future = Future()
        with self._lock:
            while True:
                if self._closed.is_set():
                    raise self._closed_error()
                if self._free and len(self._pending) < self.depth:
                    break
                if not block:
                    self.n_rejected += 1
                    raise self._full_error()
                # timed wait: re-checks the closed flag even if a racing
                # stop() notified before we started waiting
                self._not_full.wait(0.05)
            slot = self._free.pop()
            self.slab[slot] = x
            self._pending.append(
                _Request(slot, t_submit, fut, self._tid_base | next(self._tid_seq))
            )
            self._not_empty.notify()
        return fut

    def put_many(self, xs: list, t_submit: float, block: bool) -> list[Future]:
        """Enqueue a chunk under one lock acquisition.  With the reject
        policy, overflowing samples' futures are *failed* with
        :class:`QueueFullError` (and counted) instead of raising; if the
        shard closes mid-chunk the remaining futures are failed with
        :class:`EngineClosedError` — every returned Future resolves."""
        futs: list[Future] = [Future() for _ in xs]
        i, n = 0, len(xs)
        with self._lock:
            while i < n:
                if self._closed.is_set():
                    break
                space = min(len(self._free), self.depth - len(self._pending))
                if space <= 0:
                    if not block:
                        self.n_rejected += 1
                        f = futs[i]
                        if f.set_running_or_notify_cancel():
                            f.set_exception(self._full_error())
                        i += 1
                        continue
                    self._not_full.wait(0.05)
                    continue
                for j in range(i, min(i + space, n)):
                    slot = self._free.pop()
                    self.slab[slot] = xs[j]
                    self._pending.append(
                        _Request(
                            slot, t_submit, futs[j],
                            self._tid_base | next(self._tid_seq),
                        )
                    )
                i = min(i + space, n)
                self._not_empty.notify()
        for j in range(i, n):  # chunk tail cut off by a racing shutdown
            f = futs[j]
            if f.set_running_or_notify_cancel():
                f.set_exception(self._closed_error())
        return futs

    # -- dispatcher ----------------------------------------------------
    def run(self) -> None:
        while True:
            batch, t_first = self._collect()
            if batch:
                with trace.span("serve.batch", shard=self.idx, n=len(batch)):
                    self._execute(batch, t_first)
            elif self._stop.is_set():
                break
        self._fail_pending()
        self._drained.set()

    def _collect(self) -> tuple[list[_Request], float]:
        with self._lock:
            while not self._pending:
                if self._stop.is_set():
                    return [], 0.0
                self._not_empty.wait(0.05)
            t_first = time.perf_counter()
            if len(self._pending) < self.max_batch and not self._stop.is_set():
                deadline = t_first + self.max_wait_s
                while len(self._pending) < self.max_batch:
                    rem = deadline - time.perf_counter()
                    if rem <= 0 or self._stop.is_set():
                        break
                    self._not_empty.wait(min(rem, 0.02))
            n = min(len(self._pending), self.max_batch)
            batch = [self._pending.popleft() for _ in range(n)]
            self._not_full.notify_all()
            return batch, t_first

    def _free_slots(self, slots: list) -> None:
        with self._lock:
            self._free.extend(slots)
            self._not_full.notify_all()

    def _fail_pending(self) -> None:
        """Fail any requests still queued once the dispatcher is gone
        (e.g. the drain timed out) instead of leaving their futures to
        hang until the client's result() timeout."""
        with self._lock:
            reqs = list(self._pending)
            self._pending.clear()
            self._free.extend(r.slot for r in reqs)
            self._not_full.notify_all()
        for r in reqs:
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(self._closed_error())

    def _bucket(self, n: int) -> int:
        for b in self.runner.buckets:
            if b >= n:
                return b
        return self.runner.buckets[-1]

    def _execute(self, batch: list[_Request], t_first: float) -> None:
        t_formed = time.perf_counter()
        # claim the futures; drop any the client cancelled while queued
        claimed = [r for r in batch if r.future.set_running_or_notify_cancel()]
        self.stage.add("batch_form", t_formed - t_first)
        slots = [r.slot for r in batch]
        if not claimed:
            self._free_slots(slots)
            return
        self.stage.add(
            "queue_wait",
            sum(t_formed - r.t_submit for r in claimed),
            len(claimed),
        )
        n = len(claimed)
        b = self._bucket(n)
        x = self._scratch[b]
        try:
            try:
                x[:n] = self.slab[[r.slot for r in claimed]]
                if n < b:
                    x[n:] = 0
            finally:
                self._free_slots(slots)  # slots recycle even on failure
            t_pad = time.perf_counter()
            self.stage.add("pad", t_pad - t_formed)
            y = np.asarray(self._fn(x))
        except Exception as e:  # resolve futures instead of killing the thread
            for r in claimed:
                r.future.set_exception(e)
            return
        t_done = time.perf_counter()
        self.stage.add("dispatch", t_done - t_pad)
        lats = []
        for i, r in enumerate(claimed):
            r.future.set_result(y[i])
            lats.append(t_done - r.t_submit)
        self.metrics.record_many(lats, t_done)
        self.n_batches += 1
        # counted only on success, keeping sum(bucket_hits) == n_batches
        self.bucket_hits[b] += 1
        jc = self.runner.jit_compiles
        if not jc[b]:
            jc[b] = 1  # first dispatch of this shape compiled (any shard)
        self._occupancy_sum += n / b
        t_out = time.perf_counter()
        self.stage.add("copy_out", t_out - t_done)
        self._observe_batch(claimed, lats, b, n, t_first, t_formed, t_pad, t_done, t_out)

    def _observe_batch(
        self, claimed, lats, b, n, t_first, t_formed, t_pad, t_done, t_out
    ) -> None:
        """Feed the per-stage histograms, the flight recorder, and the
        process-registry gauges after a successful batch.  This thread is
        the sole writer of all three, so the path stays lock-free; the
        batch-shared stage times are charged to every request's flight
        record while queue_wait stays per-request."""
        bf_us = (t_formed - t_first) * 1e6
        pad_us = (t_pad - t_formed) * 1e6
        disp_us = (t_done - t_pad) * 1e6
        out_us = (t_out - t_done) * 1e6
        hists = self.stage_hist
        hists["batch_form"].observe(bf_us)
        hists["pad"].observe(pad_us)
        hists["dispatch"].observe(disp_us)
        hists["copy_out"].observe(out_us)
        qh = hists["queue_wait"]
        fl = self.flight
        ts_us = t_done * 1e6
        for r, lat in zip(claimed, lats):
            qw_us = (t_formed - r.t_submit) * 1e6
            qh.observe(qw_us)
            fl.record(
                r.tid, self.idx, b, n, lat * 1e6,
                (qw_us, bf_us, pad_us, disp_us, out_us), ts_us=ts_us,
            )
        # unlocked reads: both lens are single CPython ops, and a gauge
        # only needs to be approximately current
        reg = get_registry()
        model = self.runner.model_name
        reg.set_gauge(
            "serve_queue_depth", len(self._pending), model=model, shard=self.idx
        )
        reg.set_gauge(
            "serve_slab_occupancy",
            1.0 - len(self._free) / self.slab.shape[0],
            model=model, shard=self.idx,
        )

    # -- control -------------------------------------------------------
    def initiate_stop(self) -> None:
        self._stop.set()
        with self._lock:
            self._not_empty.notify_all()
            self._not_full.notify_all()

    def snapshot(self) -> dict:
        with self._lock:
            qsize = len(self._pending)
            n_rejected = self.n_rejected
        n_batches = self.n_batches
        return {
            "shard": self.idx,
            "n_batches": n_batches,
            "n_rejected": n_rejected,
            "n_requests": self.metrics.n_total,
            "queue_depth": qsize,
            "mean_batch_occupancy": (
                self._occupancy_sum / n_batches if n_batches else 0.0
            ),
            "bucket_hits": {int(b): int(c) for b, c in self.bucket_hits.items()},
            "per_stage": self.stage.snapshot(),
            "flight": self.flight.snapshot(),
        }


class _ModelRunner:
    """One registered model: shared jitted forward + N dispatch shards."""

    def __init__(
        self,
        name: str,
        design: CompiledDesign,
        max_batch: int,
        queue_depth: int,
        max_wait_us: float,
        buckets: tuple[int, ...] | None,
        shards: int = 1,
    ):
        self.model_name = name
        self.design = design
        self.max_batch = max_batch
        self.max_wait_s = max_wait_us * 1e-6
        self.buckets = tuple(sorted(buckets)) if buckets else _default_buckets(max_batch)
        if self.buckets[-1] < max_batch:
            raise ValueError("largest bucket must cover max_batch")
        self.in_shape = tuple(design.in_shape)
        self._fn = jax.jit(design.forward_int)
        # which bucket shapes have been jit-compiled (0/1 per bucket;
        # jax caches per shape for a fixed design, and the jitted fn is
        # shared by every shard).  A flag is set only *after* a trace
        # actually completed — warmup or first dispatch — so a warmup
        # that raises mid-loop never reports untraced buckets as
        # compiled.  Without an up-front warmup, flags flipping
        # mid-traffic are exactly the requests that paid a compile.
        self.jit_compiles: dict[int, int] = {b: 0 for b in self.buckets}
        self.n_shards = max(1, int(shards))
        # the per-model queue_depth backpressure budget is divided
        # across shards (ceil, so capacity never shrinks below it)
        depth = -(-queue_depth // self.n_shards)
        self._closed = threading.Event()
        self.shards = [_Shard(self, i, depth) for i in range(self.n_shards)]
        self._rr = itertools.count()  # round-robin placement cursor

    def start(self) -> None:
        for sh in self.shards:
            sh.start()

    # -- serving -------------------------------------------------------
    def submit_one(self, x: np.ndarray, t_submit: float, block: bool) -> Future:
        sh = self.shards[next(self._rr) % self.n_shards]
        return sh.put_one(x, t_submit, block)

    def submit_many(self, xs: list, t_submit: float, block: bool) -> list[Future]:
        if self.n_shards == 1 or len(xs) <= 1:
            sh = self.shards[next(self._rr) % self.n_shards]
            return sh.put_many(xs, t_submit, block)
        # contiguous chunks, one per shard round-robin: one lock
        # acquisition per shard instead of one per request
        chunk = -(-len(xs) // self.n_shards)
        futs: list[Future] = []
        for i in range(0, len(xs), chunk):
            sh = self.shards[next(self._rr) % self.n_shards]
            futs.extend(sh.put_many(xs[i : i + chunk], t_submit, block))
        return futs

    # -- control -------------------------------------------------------
    def warmup(self) -> float:
        """Compile every bucket shape up front; returns wall seconds.
        Flags are set per bucket only after its trace+run returned, so a
        mid-loop failure leaves only truthful flags behind."""
        t0 = time.perf_counter()
        for b in self.buckets:
            np.asarray(self._fn(np.zeros((b, *self.in_shape), np.int32)))
            self.jit_compiles[b] = 1
        return time.perf_counter() - t0

    def stop(self, timeout: float = 5.0) -> None:
        # closed first: from here on every enqueue attempt fails fast
        # (checked under the shard lock, closing the put-after-sweep
        # race); already-queued requests are still drained and served.
        self._closed.set()
        for sh in self.shards:
            sh.initiate_stop()
        deadline = time.perf_counter() + timeout
        for sh in self.shards:
            sh._drained.wait(max(0.0, deadline - time.perf_counter()))
        for sh in self.shards:
            sh._fail_pending()  # drain timed out: fail leftovers loudly

    def stats(self) -> dict:
        shard_snaps = [sh.snapshot() for sh in self.shards]
        s = LatencyRecorder.merged_snapshot([sh.metrics for sh in self.shards])
        bucket_hits = {int(b): 0 for b in self.buckets}
        n_batches = n_rejected = qdepth = 0
        occupancy = 0.0
        for sh, snap in zip(self.shards, shard_snaps):
            n_batches += snap["n_batches"]
            n_rejected += snap["n_rejected"]
            qdepth += snap["queue_depth"]
            occupancy += sh._occupancy_sum
            for b, c in snap["bucket_hits"].items():
                bucket_hits[b] += c
        s.update(
            model=self.model_name,
            n_shards=self.n_shards,
            n_batches=n_batches,
            n_rejected=n_rejected,
            queue_depth=qdepth,
            mean_batch_occupancy=(occupancy / n_batches if n_batches else 0.0),
            buckets=list(self.buckets),
            # aggregated bucket hit histogram + which bucket shapes have
            # been jit compiled; per-shard histograms (each satisfying
            # sum(bucket_hits) == n_batches) live under "shards"
            bucket_hits=bucket_hits,
            jit_compiles={int(b): int(c) for b, c in self.jit_compiles.items()},
            n_jit_compiles=int(sum(self.jit_compiles.values())),
            per_stage=StageAccumulator.merged_snapshot(
                [sh.stage for sh in self.shards]
            ),
            # cross-shard flight view: overall slowest-K request records
            # with their full per-stage breakdowns (p99 postmortems)
            flight=FlightRecorder.merged([sh.flight for sh in self.shards]),
            shards=shard_snaps,
        )
        return s


class ServeEngine:
    """Multi-model registry + sharded microbatched dispatch over
    compiled designs.

    The canonical way to set knobs is ``config=``, a
    :class:`repro.flow.ServeConfig` (max_batch, max_wait_us,
    queue_depth, backpressure, buckets, shards); this is what
    ``Flow.serve`` constructs.  The individual kwargs are a deprecated
    shim kept for one release (``overflow`` maps to ``backpressure``):
    they construct the equivalent config and delegate.

    ``register`` rejects duplicate model names loudly — replacing a
    model in place would silently mix two designs' results under one
    name.  Rolling a model forward is a *versioning* operation:
    ``repro.flow.Deployment.register(name, design, version=...)`` gives
    register-v2 / atomic-alias-flip / drain-v1 semantics on top of this
    engine.
    """

    def __init__(
        self,
        max_batch=UNSET,
        queue_depth=UNSET,
        max_wait_us=UNSET,
        buckets=UNSET,
        overflow=UNSET,
        config: ServeConfig | None = None,
    ):
        legacy = {
            name: val
            for name, val in (
                ("max_batch", max_batch),
                ("queue_depth", queue_depth),
                ("max_wait_us", max_wait_us),
                ("buckets", buckets),
                ("overflow", overflow),
            )
            if val is not UNSET
        }
        config = resolve_legacy(
            "ServeEngine", config, legacy, ServeConfig, _serve_config_from_legacy
        )
        self.config = config
        self.max_batch = config.max_batch
        self.queue_depth = config.queue_depth
        self.max_wait_us = config.max_wait_us
        self.buckets = config.buckets
        self.overflow = config.backpressure
        self.shards = config.shards
        self._runners: dict[str, _ModelRunner] = {}
        self._lock = threading.Lock()

    # -- registry ------------------------------------------------------
    def register(
        self,
        name: str,
        design: CompiledDesign | str | Path,
        warmup: bool = False,
    ) -> CompiledDesign:
        """Register a design (or load one from an artifact path)."""
        if not isinstance(design, CompiledDesign):
            design = load_design(design)
        runner = _ModelRunner(
            name, design, self.max_batch, self.queue_depth,
            self.max_wait_us, self.buckets, self.shards,
        )
        with self._lock:
            if name in self._runners:
                # never replace silently: two designs would be mixed under
                # one name.  Version rollout lives in flow.Deployment.
                raise ValueError(
                    f"model {name!r} already registered (roll a new version "
                    "via repro.flow.Deployment.register(..., version=))"
                )
            self._runners[name] = runner
        try:
            if warmup:
                runner.warmup()
            runner.start()
        except BaseException:  # failed warmup/start must not leave a dead entry
            with self._lock:
                self._runners.pop(name, None)
            raise
        return design

    def unregister(self, name: str, timeout: float = 5.0) -> None:
        """Drop a model after draining its queues (waiting up to
        ``timeout`` seconds for the dispatchers to finish; requests
        still queued after that are failed loudly, never left hanging)."""
        with self._lock:
            runner = self._runners.pop(name)
        runner.stop(timeout)

    def models(self) -> list[str]:
        with self._lock:
            return sorted(self._runners)

    def _runner(self, name: str) -> _ModelRunner:
        try:
            return self._runners[name]
        except KeyError:
            raise KeyError(f"model {name!r} is not registered") from None

    # -- serving -------------------------------------------------------
    def _validate(self, name: str, runner: _ModelRunner, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x)
        if x.shape != runner.in_shape:
            raise ValueError(
                f"model {name!r} expects one sample of shape {runner.in_shape}, "
                f"got {x.shape}"
            )
        if not np.issubdtype(x.dtype, np.integer):
            raise TypeError(
                f"model {name!r} expects integer-grid samples, got dtype "
                f"{x.dtype} (quantize floats with the design's in_quant first)"
            )
        return x

    def submit(self, name: str, x: np.ndarray) -> Future:
        """Enqueue one sample (integer grid, shape ``in_shape``).

        May raise :class:`QueueFullError` (reject policy, queue at
        capacity) or :class:`EngineClosedError` (the submit raced
        ``unregister``/``shutdown``; under a :class:`repro.flow.Deployment`
        rollout the deployment layer retries onto the new version)."""
        runner = self._runner(name)
        x = self._validate(name, runner, x)
        return runner.submit_one(
            x, time.perf_counter(), block=self.overflow != "reject"
        )

    def submit_batch(self, name: str, xs) -> list[Future]:
        """Enqueue many samples at once; returns one Future per sample.

        Amortizes per-request overhead (registry lookup, validation,
        clock read, shard lock) across the batch — the high-throughput
        entrypoint for clients that already hold several requests.
        ``xs`` is an iterable of samples or an ``[n, *in_shape]`` array;
        chunks are spread across shards.

        Backpressure mirrors ``submit`` per sample, except that with the
        "reject" policy an overflowing sample's Future is *failed* with
        :class:`QueueFullError` (and counted) instead of raising, so one
        full queue cannot lose the whole batch; samples cut off by a
        racing shutdown are failed with :class:`EngineClosedError`.
        Every returned Future resolves.
        """
        runner = self._runner(name)
        xs = [self._validate(name, runner, x) for x in xs]
        return runner.submit_many(
            xs, time.perf_counter(), block=self.overflow != "reject"
        )

    def infer(self, name: str, x: np.ndarray, timeout: float | None = 30.0):
        """Synchronous single-sample convenience wrapper."""
        return self.submit(name, x).result(timeout)

    def warmup(self, name: str) -> float:
        return self._runner(name).warmup()

    def stats(self, name: str | None = None) -> dict:
        if name is not None:
            return self._runner(name).stats()
        with self._lock:
            runners = list(self._runners.items())
        return {n: r.stats() for n, r in runners}

    def metrics_text(self) -> str:
        """Prometheus text exposition (format 0.0.4) over every model.

        Families are derived from the live runners — request/batch/reject
        counters, per-shard queue-depth gauges, per-bucket hit counters,
        per-stage wall totals and µs histograms, and latency-percentile
        gauges — so scraping this endpoint and reading ``stats()`` can
        never disagree.  Process-wide solver/compiler counters live in
        ``repro.obs.metrics.get_registry()`` (exposed by
        ``benchmarks/run.py obs``), not here, to avoid double counting.
        """
        with self._lock:
            runners = list(self._runners.items())
        req, batches, rejected, qd, bucket, jit = [], [], [], [], [], []
        stage_tot, stage_hist, lat = [], [], []
        for name, r in runners:
            s = r.stats()
            m = {"model": name}
            req.append((m, s["n_requests"]))
            batches.append((m, s["n_batches"]))
            rejected.append((m, s["n_rejected"]))
            jit.append((m, s["n_jit_compiles"]))
            for snap in s["shards"]:
                qd.append(
                    ({"model": name, "shard": snap["shard"]}, snap["queue_depth"])
                )
            for b, c in s["bucket_hits"].items():
                bucket.append(({"model": name, "bucket": b}, c))
            for st in StageAccumulator.STAGES:
                stage_tot.append(
                    ({"model": name, "stage": st}, s["per_stage"][st]["total_ms"] / 1e3)
                )
                stage_hist.append(
                    (
                        {"model": name, "stage": st},
                        Histogram.merged(sh.stage_hist[st] for sh in r.shards),
                    )
                )
            if s["n_latency_samples"]:
                for q in ("p50", "p99"):
                    lat.append(({"model": name, "quantile": q}, s[f"{q}_ms"]))
        families = [
            ("serve_requests_total", "counter", "requests completed", req),
            ("serve_batches_total", "counter", "batches dispatched", batches),
            ("serve_rejected_total", "counter",
             "requests rejected by backpressure", rejected),
            ("serve_queue_depth", "gauge", "queued requests per shard", qd),
            ("serve_bucket_hits_total", "counter",
             "batches dispatched per bucket shape", bucket),
            ("serve_jit_compiled_buckets", "gauge",
             "bucket shapes jit-compiled so far", jit),
            ("serve_stage_seconds_total", "counter",
             "wall seconds charged per dispatch stage", stage_tot),
            ("serve_stage_us", "histogram",
             "per-stage wall microseconds per batch (queue_wait: per request)",
             stage_hist),
            ("serve_latency_ms", "gauge",
             "end-to-end latency percentiles", lat),
        ]
        return render_prometheus(families)

    # -- lifecycle -----------------------------------------------------
    def shutdown(self, timeout: float = 5.0) -> None:
        """Stop all dispatchers after draining their queues."""
        with self._lock:
            runners = list(self._runners.values())
            self._runners.clear()
        for r in runners:
            r.stop(timeout)

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
