"""Deployable DA runtime: compile once, serve many.

    save_design / load_design   no-pickle .npz + JSON design artifacts
                                (cold-start in ms, zero solver calls,
                                crash-safe ordered commit)
    ServeEngine                 microbatched multi-model serving engine
                                with deadlines, circuit breaking and
                                shard supervision
    CircuitBreaker              closed/open/half-open dispatch breaker
    LatencyRecorder             p50/p95/p99 + throughput accounting
"""

from .artifact import (
    FORMAT_NAME,
    FORMAT_VERSION,
    ArtifactCorruptError,
    load_design,
    save_design,
)
from .engine import (
    CircuitOpenError,
    DeadlineExceededError,
    EngineClosedError,
    ModelUnhealthyError,
    QueueFullError,
    ServeEngine,
    ShardCrashedError,
)
from .metrics import LatencyRecorder, StageAccumulator, percentile
from .resilience import CircuitBreaker

__all__ = [
    "ArtifactCorruptError",
    "CircuitBreaker",
    "CircuitOpenError",
    "DeadlineExceededError",
    "EngineClosedError",
    "FORMAT_NAME",
    "FORMAT_VERSION",
    "LatencyRecorder",
    "ModelUnhealthyError",
    "QueueFullError",
    "ServeEngine",
    "ShardCrashedError",
    "StageAccumulator",
    "load_design",
    "percentile",
    "save_design",
]
