"""Deployable DA runtime: compile once, serve many.

    save_design / load_design   no-pickle .npz + JSON design artifacts
                                (cold-start in ms, zero solver calls)
    ServeEngine                 microbatched multi-model serving engine
    LatencyRecorder             p50/p95/p99 + throughput accounting
"""

from .artifact import FORMAT_NAME, FORMAT_VERSION, load_design, save_design
from .engine import EngineClosedError, QueueFullError, ServeEngine
from .metrics import LatencyRecorder, StageAccumulator, percentile

__all__ = [
    "EngineClosedError",
    "FORMAT_NAME",
    "FORMAT_VERSION",
    "LatencyRecorder",
    "QueueFullError",
    "ServeEngine",
    "StageAccumulator",
    "load_design",
    "percentile",
    "save_design",
]
