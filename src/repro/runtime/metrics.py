"""Latency / throughput / per-stage accounting for the serving engine.

``LatencyRecorder`` keeps raw per-request latencies (seconds, submit ->
result) up to a cap and first/last completion timestamps; ``snapshot``
reduces them to the usual serving report: p50/p95/p99/mean/max latency
in milliseconds plus the completed-request rate over the observation
window.  The sharded engine keeps one recorder per dispatcher shard
(each appended to by exactly one thread, so the hot path takes no
locks) and merges them with :meth:`LatencyRecorder.merged_snapshot`.

``StageAccumulator`` is the per-stage side of the story — in the spirit
of rule4ml / hft-latency-lab stage-timestamped accounting ("measure
where the time actually goes"): each dispatched batch contributes wall
seconds to the five serving stages

    queue_wait   submit -> dequeue, summed per request
    batch_form   batching window after the first request of the batch
    pad          slab gather + zero-pad into the bucket-shaped scratch
    dispatch     jitted forward call (incl. blocking on the result)
    copy_out     future resolution + latency recording

so ``stats()`` can report where a request's latency budget actually
goes instead of one opaque end-to-end number.  Accumulators are
single-writer (one per shard) and merged at snapshot time.
"""

from __future__ import annotations

import random
import time
from collections.abc import Iterable, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of unsorted values."""
    if not values:
        return float("nan")
    s = sorted(values)
    k = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return s[k]


def _reduce(lat: list, n_total: int, t_first: float | None,
            t_last: float | None) -> dict:
    span = (
        (t_last - t_first)
        if (t_first is not None and t_last is not None)
        else 0.0
    )
    return {
        "n_requests": n_total,
        "n_latency_samples": len(lat),
        "n_sampled_out": max(0, n_total - len(lat)),
        "window_s": span,
        "throughput_rps": (n_total / span) if span > 0 else 0.0,
        "p50_ms": percentile(lat, 50) * 1e3 if lat else float("nan"),
        "p95_ms": percentile(lat, 95) * 1e3 if lat else float("nan"),
        "p99_ms": percentile(lat, 99) * 1e3 if lat else float("nan"),
        "mean_ms": (sum(lat) / len(lat) * 1e3) if lat else float("nan"),
        "max_ms": max(lat) * 1e3 if lat else float("nan"),
    }


class LatencyRecorder:
    """Bounded per-request latency log with throughput bookkeeping.

    Beyond ``max_samples`` the recorder switches to reservoir sampling
    (Algorithm R, deterministic seed) so long soaks keep a uniform
    sample over the *whole* window instead of freezing percentiles on
    the first ``max_samples`` requests; ``n_sampled_out`` in snapshots
    counts observations not currently held in the reservoir.
    """

    def __init__(self, max_samples: int = 500_000, seed: int = 0):
        self.max_samples = max_samples
        self.seed = seed
        self._rng = random.Random(seed)
        self._lat: list[float] = []
        self.n_total = 0
        self.t_first: float | None = None
        self.t_last: float | None = None

    @property
    def n_sampled_out(self) -> int:
        """Observations seen but not currently held in the reservoir."""
        return max(0, self.n_total - len(self._lat))

    def record(self, latency_s: float, now: float | None = None) -> None:
        now = time.perf_counter() if now is None else now
        if self.t_first is None:
            self.t_first = now
        self.t_last = now
        self.n_total += 1
        if len(self._lat) < self.max_samples:
            self._lat.append(latency_s)
        else:
            # Algorithm R: keep the i-th observation with p = cap/i
            j = self._rng.randrange(self.n_total)
            if j < self.max_samples:
                self._lat[j] = latency_s

    def record_many(self, latencies_s: Sequence[float],
                    now: float | None = None) -> None:
        """Record one batch of latencies with a single timestamp — the
        dispatcher's per-batch path (one ``extend`` instead of a Python
        call per request until the reservoir fills)."""
        if not latencies_s:
            return
        now = time.perf_counter() if now is None else now
        if self.t_first is None:
            self.t_first = now
        self.t_last = now
        room = self.max_samples - len(self._lat)
        if room >= len(latencies_s):
            self.n_total += len(latencies_s)
            self._lat.extend(latencies_s)
            return
        if room > 0:
            self.n_total += room
            self._lat.extend(latencies_s[:room])
            latencies_s = latencies_s[room:]
        rng = self._rng
        cap = self.max_samples
        lat = self._lat
        n = self.n_total
        for v in latencies_s:
            n += 1
            j = rng.randrange(n)
            if j < cap:
                lat[j] = v
        self.n_total = n

    def reset(self) -> None:
        self.__init__(self.max_samples, self.seed)

    def snapshot(self) -> dict:
        lat = list(self._lat)  # copy: recording may continue concurrently
        return _reduce(lat, self.n_total, self.t_first, self.t_last)

    @staticmethod
    def merged_snapshot(recorders: Iterable["LatencyRecorder"]) -> dict:
        """One snapshot over several recorders (per-shard recorders of
        one model): raw samples are pooled so the percentiles are exact
        over the union, not an average of per-shard percentiles."""
        lat: list[float] = []
        n_total = 0
        t_first: float | None = None
        t_last: float | None = None
        for r in recorders:
            lat.extend(r._lat)
            n_total += r.n_total
            if r.t_first is not None:
                t_first = r.t_first if t_first is None else min(t_first, r.t_first)
            if r.t_last is not None:
                t_last = r.t_last if t_last is None else max(t_last, r.t_last)
        return _reduce(lat, n_total, t_first, t_last)


class StageAccumulator:
    """Per-stage wall-time totals for the dispatch path (single writer).

    ``add(stage, seconds, n)`` charges ``seconds`` of wall time and ``n``
    units to a stage (units are requests for ``queue_wait``, batches for
    the others — the snapshot reports both the total and the mean per
    unit so the two kinds stay interpretable).
    """

    STAGES = ("queue_wait", "batch_form", "pad", "dispatch", "copy_out")

    def __init__(self):
        self.total_s = {s: 0.0 for s in self.STAGES}
        self.count = {s: 0 for s in self.STAGES}

    def add(self, stage: str, seconds: float, n: int = 1) -> None:
        self.total_s[stage] += seconds
        self.count[stage] += n

    def snapshot(self) -> dict:
        return {
            s: {
                "total_ms": self.total_s[s] * 1e3,
                "count": self.count[s],
                "mean_us": (
                    self.total_s[s] / self.count[s] * 1e6
                    if self.count[s]
                    else 0.0
                ),
            }
            for s in self.STAGES
        }

    @staticmethod
    def merged_snapshot(accs: Iterable["StageAccumulator"]) -> dict:
        total = {s: 0.0 for s in StageAccumulator.STAGES}
        count = {s: 0 for s in StageAccumulator.STAGES}
        for a in accs:
            for s in StageAccumulator.STAGES:
                total[s] += a.total_s[s]
                count[s] += a.count[s]
        return {
            s: {
                "total_ms": total[s] * 1e3,
                "count": count[s],
                "mean_us": (total[s] / count[s] * 1e6) if count[s] else 0.0,
            }
            for s in StageAccumulator.STAGES
        }
