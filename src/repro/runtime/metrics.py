"""Latency / throughput accounting for the serving engine.

The recorder keeps raw per-request latencies (seconds, submit -> result)
up to a cap and first/last completion timestamps; ``snapshot`` reduces
them to the usual serving report: p50/p95/p99/mean/max latency in
milliseconds plus the completed-request rate over the observation
window.  Appends rely on the GIL for atomicity (single list append per
request), so the hot path takes no locks.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of unsorted values."""
    if not values:
        return float("nan")
    s = sorted(values)
    k = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return s[k]


class LatencyRecorder:
    """Bounded per-request latency log with throughput bookkeeping."""

    def __init__(self, max_samples: int = 500_000):
        self.max_samples = max_samples
        self._lat: list[float] = []
        self.n_total = 0
        self.n_dropped = 0  # recorded beyond max_samples (counted, not stored)
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None

    def record(self, latency_s: float, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        if self.t_first is None:
            self.t_first = now
        self.t_last = now
        self.n_total += 1
        if len(self._lat) < self.max_samples:
            self._lat.append(latency_s)
        else:
            self.n_dropped += 1

    def reset(self) -> None:
        self.__init__(self.max_samples)

    def snapshot(self) -> dict:
        lat = list(self._lat)  # copy: recording may continue concurrently
        span = (
            (self.t_last - self.t_first)
            if (self.t_first is not None and self.t_last is not None)
            else 0.0
        )
        return {
            "n_requests": self.n_total,
            "n_latency_samples": len(lat),
            "window_s": span,
            "throughput_rps": (self.n_total / span) if span > 0 else 0.0,
            "p50_ms": percentile(lat, 50) * 1e3 if lat else float("nan"),
            "p95_ms": percentile(lat, 95) * 1e3 if lat else float("nan"),
            "p99_ms": percentile(lat, 99) * 1e3 if lat else float("nan"),
            "mean_ms": (sum(lat) / len(lat) * 1e3) if lat else float("nan"),
            "max_ms": max(lat) * 1e3 if lat else float("nan"),
        }
