"""Circuit breaker for the serve dispatch path.

One :class:`CircuitBreaker` per registered model (shared across that
model's dispatch shards) tracks consecutive jit-dispatch failures and
cuts the failing path off instead of letting it take every batch down:

    closed ──(threshold consecutive failures)──> open
    open ──(cooldown expires)──> half_open (admits ONE probe batch)
    half_open ──probe ok──> closed              (cooldown resets)
    half_open ──probe fails──> open             (cooldown doubles, capped)

While open, the dispatcher either fails batches fast with
``CircuitOpenError`` or — with ``ServeConfig.fallback="interpreter"`` —
serves them through the bit-exact numpy interpreter, so a poisoned jit
cache degrades throughput instead of correctness.

The breaker is touched once per *batch* (not per request) and its lock
protects only a handful of scalar fields, so it adds nothing measurable
to the dispatch path.  Transition events are pushed to an optional
``on_event`` callback **outside** the lock (the serve engine feeds them
to the flight recorder and the metrics registry).
"""

from __future__ import annotations

import threading
import time
from collections.abc import Callable

__all__ = ["CircuitBreaker"]


class CircuitBreaker:
    """Closed / open / half-open breaker with capped exponential backoff."""

    def __init__(
        self,
        threshold: int = 8,
        cooldown_s: float = 0.25,
        cooldown_max_s: float = 8.0,
        clock: Callable[[], float] = time.monotonic,
        on_event: Callable[[str, dict], None] | None = None,
    ) -> None:
        if threshold < 1:
            raise ValueError("breaker threshold must be >= 1")
        if cooldown_s <= 0 or cooldown_max_s < cooldown_s:
            raise ValueError("need 0 < cooldown_s <= cooldown_max_s")
        self.threshold = int(threshold)
        self.cooldown_base_s = float(cooldown_s)
        self.cooldown_max_s = float(cooldown_max_s)
        self._clock = clock
        self._on_event = on_event
        self._lock = threading.Lock()
        self._state = "closed"
        self._failures = 0  # consecutive, while closed
        self._cooldown_s = self.cooldown_base_s
        self._open_until = 0.0
        self._probing = False  # half-open admits one probe at a time
        self.n_trips = 0
        self.n_reopens = 0
        self.n_recoveries = 0

    # -- dispatch-side API ---------------------------------------------
    def route(self) -> str:
        """Route one batch: "run" (closed), "probe" (half-open trial —
        caller MUST follow up with ``record(..., probe=True)``), or
        "reject" (open / a probe is already in flight)."""
        with self._lock:
            if self._state == "closed":
                return "run"
            if self._state == "open":
                if self._clock() >= self._open_until:
                    self._state = "half_open"
                    self._probing = True
                    return "probe"
                return "reject"
            # half_open
            if self._probing:
                return "reject"
            self._probing = True
            return "probe"

    def record(self, ok: bool, probe: bool = False) -> None:
        """Record one dispatch outcome (``probe=True`` iff ``route()``
        said "probe" for this batch)."""
        event: tuple[str, dict] | None = None
        with self._lock:
            if probe:
                self._probing = False
                if self._state == "half_open":
                    if ok:
                        self._state = "closed"
                        self._failures = 0
                        self._cooldown_s = self.cooldown_base_s
                        self.n_recoveries += 1
                        event = ("breaker_closed", self._snapshot_locked())
                    else:
                        self._cooldown_s = min(
                            self._cooldown_s * 2.0, self.cooldown_max_s
                        )
                        self._state = "open"
                        self._open_until = self._clock() + self._cooldown_s
                        self.n_reopens += 1
                        event = ("breaker_reopened", self._snapshot_locked())
            elif self._state == "closed":
                if ok:
                    self._failures = 0
                else:
                    self._failures += 1
                    if self._failures >= self.threshold:
                        self._state = "open"
                        self._open_until = self._clock() + self._cooldown_s
                        self.n_trips += 1
                        event = ("breaker_open", self._snapshot_locked())
            # outcomes of batches routed before a trip land while open:
            # they carry no new information, drop them
        if event is not None and self._on_event is not None:
            self._on_event(*event)

    # -- introspection --------------------------------------------------
    def _snapshot_locked(self) -> dict:
        return {
            "state": self._state,
            "consecutive_failures": self._failures,
            "threshold": self.threshold,
            "cooldown_s": self._cooldown_s,
            "open_remaining_s": (
                max(0.0, self._open_until - self._clock())
                if self._state == "open"
                else 0.0
            ),
            "n_trips": self.n_trips,
            "n_reopens": self.n_reopens,
            "n_recoveries": self.n_recoveries,
        }

    def snapshot(self) -> dict:
        with self._lock:
            return self._snapshot_locked()

    @property
    def state(self) -> str:
        with self._lock:
            return self._state
