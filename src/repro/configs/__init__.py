"""Architecture registry: ``get(name)`` returns the exact assigned config,
``get_smoke(name)`` a reduced same-family variant for CPU smoke tests."""

from __future__ import annotations

from .base import SHAPES, ArchConfig, RunConfig, ShapeConfig, reduced
from .stablelm_3b import CONFIG as stablelm_3b
from .granite_20b import CONFIG as granite_20b
from .smollm_135m import CONFIG as smollm_135m
from .qwen3_32b import CONFIG as qwen3_32b
from .whisper_base import CONFIG as whisper_base
from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .internvl2_26b import CONFIG as internvl2_26b
from .jamba_v01_52b import CONFIG as jamba_v01_52b
from .kimi_k2_1t import CONFIG as kimi_k2_1t
from .qwen3_moe_30b import CONFIG as qwen3_moe_30b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        stablelm_3b,
        granite_20b,
        smollm_135m,
        qwen3_32b,
        whisper_base,
        falcon_mamba_7b,
        internvl2_26b,
        jamba_v01_52b,
        kimi_k2_1t,
        qwen3_moe_30b,
    ]
}


def get(name: str) -> ArchConfig:
    return ARCHS[name]


def get_smoke(name: str, **kw) -> ArchConfig:
    return reduced(ARCHS[name], **kw)


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """Which of the four shape cells apply (long_500k needs sub-quadratic
    attention: SSM/hybrid only — see DESIGN.md §Arch-applicability)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        shapes.append("long_500k")
    return shapes


__all__ = [
    "ARCHS",
    "SHAPES",
    "ArchConfig",
    "RunConfig",
    "ShapeConfig",
    "applicable_shapes",
    "get",
    "get_smoke",
    "reduced",
]
