"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec; the conv audio frontend is a STUB (input_specs
provides precomputed frame embeddings [B, 1500, 512]).
[arXiv:2212.04356; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_seq=1500,
)
