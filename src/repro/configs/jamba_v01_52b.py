"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
MoE 16e top-2 — Mamba+attention 1:7 interleave (one attention layer per
8), MoE every other layer.  [arXiv:2403.19887; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    ssm_state=16,
    attn_every=8,
)
