"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
(per expert) vocab=151936, MoE 128e top-8, head_dim=128, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    n_experts=128,
    experts_per_token=8,
    rope_theta=1e6,
)
