"""Architecture + run configuration schema for the LM framework.

Every assigned architecture is an ``ArchConfig`` in this package
(``--arch <id>`` in the launchers).  ``layer_pattern`` describes one
period of the (mixer, ffn) stack — the transformer scan iterates over
periods with the period body unrolled, which keeps HLO size O(period)
instead of O(n_layers) while supporting heterogeneous stacks (Jamba's
1:7 attention:Mamba interleave with MoE on odd layers).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

ATTN, SSM = "attn", "ssm"
MLP, MOE = "mlp", "moe"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # MoE ffn every k-th layer (1 = all layers when n_experts>0)
    capacity_factor: float = 1.25

    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0  # hybrid: one attention layer per `attn_every` layers

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # frontend-stub frames per example

    # --- VLM (frontend stub) ---
    vision_tokens: int = 0

    # --- numerics / perf knobs ---
    dtype: str = "bfloat16"
    remat: str = "full"  # full | dots | none
    scan_layers: bool = True
    use_flash_kernel: bool = False  # Pallas path (TPU); jnp path for dry-run
    ssm_chunk: int = 128
    # "seq": time-major sequential scan — HBM-optimal (the traffic pattern
    # of a fused kernel; ~20x less scan traffic than the Blelloch
    # associative scan XLA emits), serial depth S.  "assoc": chunked
    # associative scan — log-depth, memory-hungry.  See EXPERIMENTS.md
    # §Perf (falcon-mamba train cell).
    ssm_mode: str = "seq"

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 256) * 256

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_pattern(self) -> tuple[list[tuple[str, str | None]], int]:
        """Returns (one period of (mixer, ffn) entries, n_periods)."""
        if self.family == "ssm":
            return [(SSM, None)], self.n_layers
        if self.family == "hybrid":
            p = self.attn_every or 8
            period = []
            for i in range(p):
                mixer = ATTN if i == p // 2 else SSM
                ffn = MOE if (self.n_experts and i % max(self.moe_every, 1) == 1) else MLP
                period.append((mixer, ffn))
            assert self.n_layers % p == 0
            return period, self.n_layers // p
        ffn = MOE if self.n_experts else MLP
        if self.n_experts and self.moe_every > 1:
            period = [
                (ATTN, MOE if i % self.moe_every == self.moe_every - 1 else MLP)
                for i in range(self.moe_every)
            ]
            assert self.n_layers % self.moe_every == 0
            return period, self.n_layers // self.moe_every
        return [(ATTN, ffn)], self.n_layers

    def param_count(self) -> int:
        """Total parameters (exact for our parameterization)."""
        d, v, hd = self.d_model, self.padded_vocab, self.hd
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        total += d  # final norm
        period, n_periods = self.layer_pattern()
        for mixer, ffn in period:
            total += n_periods * d  # pre-mixer norm
            if mixer == ATTN:
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += n_periods * (q + kv + o)
                if self.qk_norm:
                    total += n_periods * 2 * hd
            else:
                di, st, dtr = self.d_inner, self.ssm_state, self.dt_rank
                total += n_periods * (
                    d * 2 * di  # in_proj
                    + di * self.ssm_conv  # conv
                    + di * (dtr + 2 * st)  # x_proj
                    + dtr * di + di  # dt_proj
                    + di * st + di  # A_log, D
                    + di * d  # out_proj
                )
            if ffn is not None:
                total += n_periods * d  # pre-ffn norm
                if ffn == MLP:
                    total += n_periods * 3 * d * self.d_ff
                else:
                    total += n_periods * (
                        d * self.n_experts + self.n_experts * 3 * d * self.d_ff
                    )
        if self.family == "encdec":
            # encoder layers (self-attn + mlp) and decoder cross-attn
            attn_p = 2 * d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            if self.qk_norm:
                attn_p += 2 * hd
            enc = self.encoder_layers * (2 * d + attn_p + 3 * d * self.d_ff)
            cross = self.n_layers * (d + attn_p)
            total += enc + cross + d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        period, n_periods = self.layer_pattern()
        n_moe = sum(1 for _, f in period if f == MOE) * n_periods
        inactive = n_moe * (self.n_experts - self.experts_per_token) * 3 * d * self.d_ff
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Training/serving run options (optimizer, parallelism, fault tol)."""

    optimizer: str = "adamw"  # adamw | adafactor
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_dtype: str | None = "float32"  # None: bf16 params are master
    state_dtype: str | None = None  # 'int8' enables 8-bit Adam states
    microbatch: int = 1  # gradient-accumulation chunks
    fsdp_over_pod: bool = False  # shard params across pods too (1T-scale)
    seq_shard: bool = False  # sequence parallelism for long-context
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


def reduced(cfg: ArchConfig, **kw) -> ArchConfig:
    """Smoke-test-sized variant of an architecture (same family/pattern)."""
    period = 1
    if cfg.family == "hybrid":
        period = cfg.attn_every or 8
    n_layers = kw.pop("n_layers", 2 * period if cfg.family == "hybrid" else 2)
    if cfg.n_experts and cfg.moe_every > 1:
        n_layers = max(n_layers, cfg.moe_every)
        n_layers -= n_layers % cfg.moe_every
    defaults = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16),
        vision_tokens=min(cfg.vision_tokens, 8),
        ssm_state=min(cfg.ssm_state, 8),
        dtype="float32",
        ssm_chunk=16,
        # tiny token counts make capacity drops likely at cf=1.25, which
        # breaks decode-vs-teacher-forcing equivalence checks; smoke
        # configs use a drop-free capacity
        capacity_factor=4.0,
    )
    defaults.update(kw)
    return replace(cfg, name=cfg.name + "-smoke", **defaults)
