"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT frontend is a STUB (input_specs provides 256
patch embeddings per image); backbone is the InternLM2-20B decoder.
[arXiv:2404.16821; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    vision_tokens=256,
)
