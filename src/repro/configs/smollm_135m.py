"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152 — llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M; hf]

9 query heads do not divide the 16-way model axis: the sharding rules
fall back to replicated attention heads (logged by the dry-run) while
d_ff=1536 still shards 16-way.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
)
