"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per expert) vocab=163840, MoE 384e top-8 — trillion-parameter MoE
(paper-table config).  61 layers is prime-ish for scanning: we scan 61
periods of one layer.  Training this at single-pod scale requires
adafactor + fsdp_over_pod (see EXPERIMENTS.md §Dry-run notes).
[arXiv:2501.kimi2; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    experts_per_token=8,
)
