"""Process-wide metrics registry: counters, gauges, histograms.

Write path is lock-free after first touch: each writing thread owns a
private *shard* (registered under the module lock exactly once) and all
``inc`` / ``set`` / ``observe`` calls mutate only that shard — the same
single-writer idiom ``runtime.metrics.LatencyRecorder`` and the serve
dispatcher shards already use.  ``snapshot()`` merges the shards:
counters sum, gauges resolve last-write-wins via a global sequence
number, histograms add bucket counts.

Exposition: ``snapshot()`` (plain dict, JSON-ready) and
``to_prometheus()`` (Prometheus text format 0.0.4) — surfaced through
``ServeEngine.metrics_text()`` and ``benchmarks/run.py obs``.

``Histogram`` is also usable standalone (the serve shards keep one per
stage and merge them in ``stats()``), with fixed exponential bucket
edges in microseconds by default.
"""

from __future__ import annotations

import bisect
import itertools
import math
import threading
from collections.abc import Iterable, Sequence

__all__ = [
    "DEFAULT_BUCKETS_US",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "render_prometheus",
]

# 1µs .. 10s, roughly 1-2-5 per decade — wide enough for both solver
# phases (ms..s) and serve stages (µs..ms)
DEFAULT_BUCKETS_US: tuple[float, ...] = (
    1.0, 2.0, 5.0,
    10.0, 20.0, 50.0,
    100.0, 200.0, 500.0,
    1_000.0, 2_000.0, 5_000.0,
    10_000.0, 20_000.0, 50_000.0,
    100_000.0, 200_000.0, 500_000.0,
    1_000_000.0, 2_000_000.0, 5_000_000.0, 10_000_000.0,
)

# global monotone sequence for gauge last-write-wins resolution across
# shards; itertools.count() bumps under the GIL without a lock
_GAUGE_SEQ = itertools.count()

LabelsT = tuple[tuple[str, str], ...]


def _labels_key(labels: dict) -> LabelsT:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _labels_str(labels: LabelsT) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class Histogram:
    """Fixed-bucket histogram with Prometheus-style cumulative export.

    Single-writer by convention (one per thread/shard); merge shards
    with :meth:`merged`.  Values are unit-free — call sites use µs.
    """

    __slots__ = ("bounds", "counts", "sum", "n")

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS_US) -> None:
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # last = +Inf overflow
        self.sum = 0.0
        self.n = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.sum += value
        self.n += 1

    def merge_from(self, other: "Histogram") -> None:
        if other.bounds != self.bounds:
            raise ValueError("cannot merge histograms with different bucket bounds")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.n += other.n

    @staticmethod
    def merged(hists: Iterable["Histogram"]) -> "Histogram":
        out: Histogram | None = None
        for h in hists:
            if out is None:
                out = Histogram(h.bounds)
            out.merge_from(h)
        return out if out is not None else Histogram()

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (0..100) by in-bucket interpolation."""
        if self.n == 0:
            return 0.0
        rank = q / 100.0 * self.n
        cum = 0
        lo = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            hi = self.bounds[i] if i < len(self.bounds) else lo * 2 or 1.0
            if cum + c >= rank:
                frac = (rank - cum) / c
                return lo + frac * (hi - lo)
            cum += c
            lo = hi
        return lo

    def snapshot(self) -> dict:
        """Cumulative-bucket dict mirroring Prometheus histogram semantics."""
        cum = 0
        buckets = {}
        for i, bound in enumerate(self.bounds):
            cum += self.counts[i]
            buckets[bound] = cum
        buckets[math.inf] = self.n
        return {"count": self.n, "sum": self.sum, "buckets": buckets}


class _Shard:
    """One thread's private slice of the registry.  Single writer."""

    __slots__ = ("counters", "gauges", "hists")

    def __init__(self) -> None:
        self.counters: dict[tuple[str, LabelsT], float] = {}
        # gauge value is (seq, value) so merge can pick the latest write
        self.gauges: dict[tuple[str, LabelsT], tuple[int, float]] = {}
        self.hists: dict[tuple[str, LabelsT], Histogram] = {}


class MetricsRegistry:
    """Counters / gauges / histograms with per-thread single-writer shards."""

    def __init__(self, hist_bounds: Sequence[float] = DEFAULT_BUCKETS_US) -> None:
        self._lock = threading.Lock()
        self._shards: list[_Shard] = []
        self._tls = threading.local()
        self._hist_bounds = tuple(hist_bounds)

    def _shard(self) -> _Shard:
        s = getattr(self._tls, "shard", None)
        if s is None:
            s = _Shard()
            with self._lock:
                self._shards.append(s)
            self._tls.shard = s
        return s

    # -- write path (lock-free after first touch) ------------------------
    def inc(self, name: str, value: float = 1.0, **labels: object) -> None:
        s = self._shard()
        key = (name, _labels_key(labels))
        s.counters[key] = s.counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels: object) -> None:
        s = self._shard()
        s.gauges[(name, _labels_key(labels))] = (next(_GAUGE_SEQ), float(value))

    def observe(self, name: str, value: float, **labels: object) -> None:
        s = self._shard()
        key = (name, _labels_key(labels))
        h = s.hists.get(key)
        if h is None:
            h = s.hists[key] = Histogram(self._hist_bounds)
        h.observe(value)

    # -- read path -------------------------------------------------------
    def _merged(self) -> tuple[dict, dict, dict]:
        with self._lock:
            shards = list(self._shards)
        counters: dict[tuple[str, LabelsT], float] = {}
        gauges: dict[tuple[str, LabelsT], tuple[int, float]] = {}
        hists: dict[tuple[str, LabelsT], Histogram] = {}
        for s in shards:
            for key, v in list(s.counters.items()):
                counters[key] = counters.get(key, 0.0) + v
            for key, sv in list(s.gauges.items()):
                cur = gauges.get(key)
                if cur is None or sv[0] > cur[0]:
                    gauges[key] = sv
            for key, h in list(s.hists.items()):
                tgt = hists.get(key)
                if tgt is None:
                    tgt = hists[key] = Histogram(h.bounds)
                tgt.merge_from(h)
        return counters, gauges, hists

    def snapshot(self) -> dict:
        """Merged view as a JSON-ready dict keyed ``name{label="v"}``."""
        counters, gauges, hists = self._merged()
        return {
            "counters": {n + _labels_str(k): v for (n, k), v in sorted(counters.items())},
            "gauges": {n + _labels_str(k): v for (n, k), (_, v) in sorted(gauges.items())},
            "histograms": {
                n + _labels_str(k): {
                    "count": h.n,
                    "sum": h.sum,
                    "p50": h.percentile(50),
                    "p99": h.percentile(99),
                }
                for (n, k), h in sorted(hists.items())
            },
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4) of the merged view."""
        counters, gauges, hists = self._merged()
        families: list[tuple[str, str, list]] = []
        for kind, data in (("counter", counters), ("gauge", gauges)):
            by_name: dict[str, list] = {}
            for (n, k), v in sorted(data.items()):
                val = v[1] if kind == "gauge" else v
                by_name.setdefault(n, []).append((k, val))
            for n, samples in by_name.items():
                families.append((n, kind, samples))
        hist_by_name: dict[str, list] = {}
        for (n, k), h in sorted(hists.items()):
            hist_by_name.setdefault(n, []).append((k, h))
        lines: list[str] = []
        for name, kind, samples in families:
            lines.append(f"# TYPE {name} {kind}")
            for k, val in samples:
                lines.append(f"{name}{_labels_str(k)} {_fmt(val)}")
        for name, samples in hist_by_name.items():
            lines.append(f"# TYPE {name} histogram")
            for k, h in samples:
                lines.extend(render_histogram_lines(name, dict(k), h))
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        with self._lock:
            for s in self._shards:
                s.counters.clear()
                s.gauges.clear()
                s.hists.clear()


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_histogram_lines(name: str, labels: dict, h: Histogram) -> list[str]:
    """Prometheus `_bucket`/`_sum`/`_count` sample lines for one histogram."""
    base = _labels_key(labels)
    lines = []
    cum = 0
    for i, bound in enumerate(h.bounds):
        cum += h.counts[i]
        lk = _labels_str(base + (("le", _fmt(bound)),))
        lines.append(f"{name}_bucket{lk} {cum}")
    lk = _labels_str(base + (("le", "+Inf"),))
    lines.append(f"{name}_bucket{lk} {h.n}")
    lines.append(f"{name}_sum{_labels_str(base)} {_fmt(h.sum)}")
    lines.append(f"{name}_count{_labels_str(base)} {h.n}")
    return lines


def render_prometheus(families: Iterable[tuple]) -> str:
    """Render ``(name, kind, help, samples)`` tuples as Prometheus text.

    ``samples`` is a list of ``(labels_dict, value)`` for counters and
    gauges, or ``(labels_dict, Histogram)`` for histograms.  Used by
    ``ServeEngine.metrics_text()`` to expose engine-derived families
    without double counting against the process registry.
    """
    lines: list[str] = []
    for name, kind, help_text, samples in families:
        if help_text:
            lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {kind}")
        for labels, value in samples:
            if kind == "histogram":
                lines.extend(render_histogram_lines(name, labels, value))
            else:
                lines.append(f"{name}{_labels_str(_labels_key(labels))} {_fmt(value)}")
    return "\n".join(lines) + "\n"


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (solver/compiler counters live here)."""
    return _REGISTRY
