"""repro.obs — unified telemetry: span tracing, metrics, flight recorder.

One observability layer consumed by the solver, the compiler, the serve
engine, and the benchmarks:

``repro.obs.trace``
    Low-overhead span tracer with per-thread ring buffers and a Chrome
    trace-event / Perfetto JSON exporter.  Disabled by default; enable
    with ``REPRO_TRACE=1`` or :func:`trace.set_enabled`.

``repro.obs.metrics``
    Process-wide registry of counters / gauges / histograms with
    single-writer per-thread shards merged at snapshot, plus JSON and
    Prometheus-text exposition.

``repro.obs.flight``
    Per-shard flight recorder: a bounded ring of per-request records
    with tail-sampling that pins the slowest-K requests' full per-stage
    breakdowns for postmortem p99 triage.

``repro.obs.solvelog``
    Structured per-solve result records (matrix statistics → adders /
    cost / depth / wall) kept in a bounded in-memory ring and optionally
    appended to a JSONL file — the training log for a future learned
    resource predictor.

Everything here is stdlib + optional numpy only; importing ``repro.obs``
never pulls in jax.
"""

from . import flight, metrics, solvelog, trace
from .flight import FlightRecorder
from .metrics import Histogram, MetricsRegistry, get_registry

__all__ = [
    "FlightRecorder",
    "Histogram",
    "MetricsRegistry",
    "flight",
    "get_registry",
    "metrics",
    "solvelog",
    "trace",
]
