"""Structured per-solve result records — the predictor's training log.

Every CMVM solve appends one flat record (matrix statistics → solver
outcome) to a bounded in-memory ring; when ``REPRO_SOLVE_LOG=/path.jsonl``
is set (or :func:`set_path` is called) records are also appended to a
JSONL file.  This is the data a rule4ml-style learned resource
estimator (PAPERS.md, arXiv 2408.05314) trains on: predict adders /
cost bits / depth / wall seconds from cheap matrix features without
running the solver.

Record schema (all scalars, JSON-ready)::

    {
      "kind": "cmvm", "engine": "arena", "dc": 2, "decomposed": true,
      "d_out": 64, "d_in": 64, "nnz": 4032, "w_max_abs": 127,
      "bits_in": 8, "adders": 312, "cost_bits": 4120, "depth": 9,
      "wall_s": 0.41, "cache_hit": false
    }

The in-memory ring is always on (a dict append per solve — solves are
milliseconds at minimum, so this is free); the JSONL sink is opt-in and
guarded by a lock because compile solves run on a thread pool.
"""

from __future__ import annotations

import json
import os
import threading
from collections import deque

__all__ = ["log_solve", "records", "reset", "set_path", "get_path"]

_RING_CAP = 4096

_lock = threading.Lock()
_ring: deque = deque(maxlen=_RING_CAP)
_path: str | None = os.environ.get("REPRO_SOLVE_LOG") or None


def set_path(path: str | None) -> None:
    """Set (or clear, with None) the JSONL sink for solve records."""
    global _path
    with _lock:
        _path = path


def get_path() -> str | None:
    return _path


def log_solve(record: dict) -> None:
    """Append one per-solve record to the ring (and JSONL sink if set)."""
    _ring.append(record)  # deque.append is atomic under the GIL
    p = _path
    if p is not None:
        line = json.dumps(record, sort_keys=True)
        with _lock:
            with open(p, "a") as fh:
                fh.write(line + "\n")


def records() -> list[dict]:
    """Snapshot of the in-memory ring, oldest first."""
    return list(_ring)


def reset() -> None:
    _ring.clear()
