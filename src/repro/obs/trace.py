"""Low-overhead span tracer with Chrome trace-event / Perfetto export.

Usage::

    from repro.obs import trace

    with trace.span("cse.select", engine="arena"):
        ...

Design constraints (this sits inside the solver hot path and the serve
dispatcher loop):

* **Disabled path is a shared no-op context manager.**  ``span(...)``
  returns a module-level singleton when tracing is off — no object
  allocation, no clock read, no thread-local lookup.  The only residual
  cost is the call itself plus the kwargs dict, which is why call sites
  keep spans at *phase* granularity (per solve / per batch), never
  per-element.

* **Per-thread ring buffers, no locks on the record path.**  Each thread
  owns a bounded event ring it alone writes; the module lock is taken
  only when a thread records its first span (buffer registration) and at
  export.  When a ring wraps, the oldest events are overwritten and
  counted in ``n_dropped``.

* **Thread-local span stacks** give each event its nesting depth so the
  exporter can emit well-formed Complete ("X") events even for spans
  closed out of wall-clock order on one thread.

Export is the Chrome trace-event JSON format (``{"traceEvents": [...]}``
with "X" duration events and "M" thread-name metadata), loadable
directly in https://ui.perfetto.dev or chrome://tracing.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections.abc import Iterator
from typing import Any

__all__ = [
    "enabled",
    "set_enabled",
    "set_capacity",
    "span",
    "instant",
    "reset",
    "export",
    "export_chrome_trace",
    "n_events",
]

DEFAULT_CAPACITY = 65536

_EPOCH = time.perf_counter()
_PID = os.getpid()

_lock = threading.Lock()
_buffers: list["_ThreadBuf"] = []
_tls = threading.local()

_capacity = int(os.environ.get("REPRO_TRACE_CAPACITY", DEFAULT_CAPACITY))
_enabled = os.environ.get("REPRO_TRACE", "").strip().lower() not in ("", "0", "false", "off")


def enabled() -> bool:
    """Whether span recording is currently on."""
    return _enabled


def set_enabled(flag: bool) -> None:
    """Turn span recording on/off process-wide (also: ``REPRO_TRACE=1``)."""
    global _enabled
    _enabled = bool(flag)


def set_capacity(capacity: int) -> None:
    """Set the per-thread ring size for buffers created *after* this call."""
    global _capacity
    if capacity < 1:
        raise ValueError("trace capacity must be >= 1")
    _capacity = int(capacity)


class _ThreadBuf:
    """One thread's event ring.  Single writer: the owning thread."""

    __slots__ = ("tid", "name", "cap", "events", "n", "stack")

    def __init__(self, tid: int, name: str, cap: int) -> None:
        self.tid = tid
        self.name = name
        self.cap = cap
        self.events: list[Any] = [None] * cap
        self.n = 0  # total events ever pushed; ring index is n % cap
        self.stack: list[str] = []  # open span names (thread-local nesting)

    def push(self, ev: tuple) -> None:
        self.events[self.n % self.cap] = ev
        self.n += 1

    def iter_events(self) -> Iterator[tuple]:
        """Yield retained events oldest-first."""
        if self.n <= self.cap:
            for i in range(self.n):
                yield self.events[i]
        else:
            start = self.n % self.cap
            for i in range(self.cap):
                yield self.events[(start + i) % self.cap]

    @property
    def n_dropped(self) -> int:
        return max(0, self.n - self.cap)


def _buf() -> _ThreadBuf:
    b = getattr(_tls, "buf", None)
    if b is None:
        b = _ThreadBuf(threading.get_ident(), threading.current_thread().name, _capacity)
        with _lock:
            _buffers.append(b)
        _tls.buf = b
    return b


class _NoopSpan:
    """Shared do-nothing context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False


_NOOP = _NoopSpan()


class span:
    """Record one Complete ("X") event spanning the ``with`` body.

    ``span(name, **attrs)`` — attrs land in the event's ``args`` and show
    up in the Perfetto slice details pane.  When tracing is disabled this
    returns a shared no-op singleton (no allocation).
    """

    __slots__ = ("name", "args", "t0", "depth")

    def __new__(cls, name: str, **attrs: Any) -> "span | _NoopSpan":
        if not _enabled:
            return _NOOP
        self = object.__new__(cls)
        self.name = name
        self.args = attrs or None
        return self

    def __init__(self, name: str, **attrs: Any) -> None:
        # attributes are set in __new__; __init__ only runs for the
        # enabled path and must not clobber them
        pass

    def __enter__(self) -> "span":
        b = _buf()
        self.depth = len(b.stack)
        b.stack.append(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: object) -> bool:
        t1 = time.perf_counter()
        b = _buf()
        if b.stack and b.stack[-1] == self.name:
            b.stack.pop()
        # (name, ts_us, dur_us, depth, args) — dur None marks an instant
        b.push((self.name, (self.t0 - _EPOCH) * 1e6, (t1 - self.t0) * 1e6, self.depth, self.args))
        return False


def instant(name: str, **attrs: Any) -> None:
    """Record a zero-duration instant event (rendered as an arrow mark)."""
    if not _enabled:
        return
    b = _buf()
    b.push((name, (time.perf_counter() - _EPOCH) * 1e6, None, len(b.stack), attrs or None))


def n_events() -> int:
    """Total retained events across all thread buffers."""
    with _lock:
        bufs = list(_buffers)
    return sum(min(b.n, b.cap) for b in bufs)


def reset() -> None:
    """Drop all recorded events (buffers stay registered to their threads)."""
    with _lock:
        for b in _buffers:
            b.n = 0
            b.events = [None] * b.cap


def export(path: str | None = None) -> dict:
    """Build (and optionally write) a Chrome trace-event JSON document.

    Merges every thread's ring into one ``{"traceEvents": [...]}`` doc
    with per-thread "M" thread_name metadata.  Timestamps are µs since
    the module import epoch, so spans from the solver pool, dispatcher
    shards, and the main thread share one timeline.
    """
    with _lock:
        bufs = list(_buffers)
    events: list[dict] = []
    n_dropped = 0
    for b in bufs:
        events.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": _PID,
                "tid": b.tid,
                "args": {"name": b.name},
            }
        )
        n_dropped += b.n_dropped
        for name, ts, dur, _depth, args in b.iter_events():
            ev = {
                "name": name,
                "cat": "repro",
                "ph": "X" if dur is not None else "i",
                "ts": round(ts, 3),
                "pid": _PID,
                "tid": b.tid,
            }
            if dur is not None:
                ev["dur"] = round(dur, 3)
            else:
                ev["s"] = "t"  # instant scope: thread
            if args:
                ev["args"] = dict(args)
            events.append(ev)
    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"producer": "repro.obs.trace", "n_dropped": n_dropped},
    }
    if path is not None:
        with open(path, "w") as fh:
            json.dump(doc, fh)
    return doc


# canonical exporter name used by docs/benchmarks; `export` is the short form
export_chrome_trace = export
