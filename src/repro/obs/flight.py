"""Per-shard flight recorder: bounded request ring + slowest-K pinning.

Each serve dispatcher shard owns one :class:`FlightRecorder` and is its
only writer, so the record path is lock-free (ring store + a bounded
min-heap update).  A record carries everything needed to explain one
request postmortem: trace id, shard, bucket, batch size, end-to-end
latency, and the per-stage µs breakdown
(queue_wait / batch_form / pad / dispatch / copy_out).

Tail sampling: besides the ring (which wraps and forgets), the recorder
pins the slowest-K requests *ever seen* so "why was that one request
8 ms" is answerable long after the ring has rolled over.  Shard
recorders merge in ``_ModelRunner.stats()`` via :meth:`merged`.

Besides per-request records, a recorder keeps a small bounded **event
ring** (:meth:`record_event`) for rare lifecycle transitions — circuit
breaker trips/recoveries, shard crashes and restarts, model-unhealthy
escalation — so a postmortem can line the slow requests up against what
the resilience machinery was doing at the time.  Events may be recorded
from any thread (``deque.append`` is atomic).
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from collections.abc import Iterable, Sequence

__all__ = ["STAGES", "FlightRecorder"]

STAGES = ("queue_wait", "batch_form", "pad", "dispatch", "copy_out")

# tie-breaker for equal-latency heap entries (records aren't orderable)
_SEQ = itertools.count()


class FlightRecorder:
    """Bounded ring of per-request records plus a slowest-K tail sample."""

    __slots__ = ("capacity", "slow_k", "_ring", "_n", "_slow", "_events", "_n_events")

    def __init__(
        self, capacity: int = 2048, slow_k: int = 16, event_capacity: int = 256
    ) -> None:
        if capacity < 1:
            raise ValueError("flight recorder capacity must be >= 1")
        self.capacity = int(capacity)
        self.slow_k = int(slow_k)
        self._ring: list = [None] * self.capacity
        self._n = 0  # total records ever; ring index is n % capacity
        self._slow: list = []  # min-heap of (lat_us, seq, record)
        self._events: deque = deque(maxlen=max(1, int(event_capacity)))
        self._n_events = 0

    def record(
        self,
        trace_id: int,
        shard: int,
        bucket: int,
        batch_size: int,
        lat_us: float,
        stages_us: Sequence[float],
        ts_us: float = 0.0,
    ) -> None:
        """Store one request record.  ``stages_us`` aligns with STAGES."""
        rec = (trace_id, shard, bucket, batch_size, lat_us, tuple(stages_us), ts_us)
        self._ring[self._n % self.capacity] = rec
        self._n += 1
        if self.slow_k > 0:
            if len(self._slow) < self.slow_k:
                heapq.heappush(self._slow, (lat_us, next(_SEQ), rec))
            elif lat_us > self._slow[0][0]:
                heapq.heapreplace(self._slow, (lat_us, next(_SEQ), rec))

    def record_event(self, kind: str, ts_us: float = 0.0, **fields) -> None:
        """Store one lifecycle event (breaker transition, shard restart,
        ...).  Bounded: the oldest events fall off; ``n_events`` keeps
        the true total.  Safe to call from any thread."""
        self._events.append({"kind": kind, "ts_us": ts_us, **fields})
        self._n_events += 1

    def events(self) -> list[dict]:
        """Retained lifecycle events, oldest first."""
        return list(self._events)

    @staticmethod
    def _as_dict(rec: tuple) -> dict:
        trace_id, shard, bucket, batch_size, lat_us, stages, ts_us = rec
        return {
            "trace_id": trace_id,
            "shard": shard,
            "bucket": bucket,
            "batch_size": batch_size,
            "lat_us": lat_us,
            "ts_us": ts_us,
            "stages_us": dict(zip(STAGES, stages)),
        }

    def recent(self, n: int | None = None) -> list[dict]:
        """Most-recent retained records, newest last."""
        held = min(self._n, self.capacity)
        take = held if n is None else min(n, held)
        out = []
        for i in range(self._n - take, self._n):
            out.append(self._as_dict(self._ring[i % self.capacity]))
        return out

    def slowest(self) -> list[dict]:
        """Pinned slowest-K records, slowest first."""
        return [self._as_dict(rec) for _, _, rec in sorted(self._slow, reverse=True)]

    def snapshot(self) -> dict:
        return {
            "n_records": self._n,
            "capacity": self.capacity,
            "n_evicted": max(0, self._n - self.capacity),
            "slowest": self.slowest(),
            "n_events": self._n_events,
            "events": self.events(),
        }

    @staticmethod
    def merged(recorders: Iterable["FlightRecorder"], slow_k: int | None = None) -> dict:
        """Cross-shard snapshot: summed counts, overall slowest-K,
        time-ordered events."""
        recs = list(recorders)
        k = slow_k if slow_k is not None else max((r.slow_k for r in recs), default=0)
        slowest: list[dict] = []
        events: list[dict] = []
        for r in recs:
            slowest.extend(r.slowest())
            events.extend(r.events())
        slowest.sort(key=lambda d: d["lat_us"], reverse=True)
        events.sort(key=lambda d: d["ts_us"])
        return {
            "n_records": sum(r._n for r in recs),
            "capacity": sum(r.capacity for r in recs),
            "n_evicted": sum(max(0, r._n - r.capacity) for r in recs),
            "slowest": slowest[:k],
            "n_events": sum(r._n_events for r in recs),
            "events": events,
        }
