"""End-to-end QAT -> da4ml deployment: the paper's headline workflow.

    PYTHONPATH=src python examples/train_jet_tagger.py

Trains the high-level-feature jet tagger (16 -> 64 -> 32 -> 16 -> 16 -> 5,
paper §6.2.1) with HGQ-style quantization-aware training on a synthetic
5-class task, then compiles it to an FPGA adder-graph design with both
strategies and verifies the integer pipeline matches the trained float
model bit-exactly.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import apply_model, init_params, models

model, in_shape, in_quant = models.jet_tagger(w_bits=6, a_bits=8)
key = jax.random.PRNGKey(0)
params, _ = init_params(key, model, in_shape)

# synthetic 5-class jet dataset: gaussian clusters + noise
kd, kw = jax.random.split(jax.random.PRNGKey(1))
centers = jax.random.normal(kw, (5, 16)) * 2.0
def make_batch(k, n=512):
    ky, kx = jax.random.split(k)
    y = jax.random.randint(ky, (n,), 0, 5)
    x = centers[y] + jax.random.normal(kx, (n, 16))
    return x, y

@jax.jit
def step(params, k, lr):
    x, y = make_batch(k)
    def loss_fn(p):
        logits, bits = apply_model(p, model, x, in_quant=in_quant, collect_bits=True)
        lp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()
        return nll + 1e-5 * bits, nll  # HGQ-style bit-count regularizer
    (loss, nll), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    return params, nll

t0 = time.time()
for i in range(300):
    key, sub = jax.random.split(key)
    params, nll = step(params, sub, 0.02)
    if i % 100 == 0:
        print(f"step {i:4d}  nll {float(nll):.3f}")
x, y = make_batch(jax.random.PRNGKey(99), 2048)
acc = (jnp.argmax(apply_model(params, model, x, in_quant=in_quant), -1) == y).mean()
print(f"trained in {time.time()-t0:.1f}s, accuracy {float(acc):.1%}")

# --- deploy: compile to adder graphs, compare strategies ---
from repro.flow import CompileConfig, Flow, SolverConfig  # noqa: E402

for strategy in ("latency", "da"):
    design = Flow.compile(
        model, params, in_shape, in_quant,
        config=CompileConfig(strategy=strategy, solver=SolverConfig(dc=2)),
    )
    print(f"\n=== strategy={strategy} ===")
    print(design.summary())

# --- bit-exactness of the deployed design (float64 reference) ---
design = Flow.compile(model, params, in_shape, in_quant)
with jax.experimental.enable_x64():
    xq = jnp.asarray(np.asarray(x[:64]), jnp.float64)
    want = apply_model(jax.tree.map(lambda a: jnp.asarray(np.asarray(a), jnp.float64), params),
                       model, xq, in_quant=in_quant)
    got = design.forward(xq)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
print("\ncompiled integer design == trained float model (bit-exact): OK")
acc_hw = (jnp.argmax(design.forward(x), -1) == y).mean()
print(f"hardware-design accuracy: {float(acc_hw):.1%}")
