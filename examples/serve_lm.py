"""Serve a small LM with batched requests (the paper's kind is real-time
inference, so the end-to-end driver is a serving loop).

    PYTHONPATH=src python examples/serve_lm.py [--arch smollm-135m]

Briefly trains a reduced same-family model on the deterministic Markov
pipeline so generation is non-trivial, then serves mixed-length batched
requests through the slot-based engine (prefill + decode with a
preallocated KV cache).
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import init_params
from repro.serve.engine import Engine, Request
from repro.train.train_lib import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm-135m")
ap.add_argument("--train-steps", type=int, default=60)
args = ap.parse_args()

cfg = configs.get_smoke(args.arch, d_model=128, n_layers=4, d_ff=256)
print(f"serving {cfg.name}: {cfg.param_count():,} params")

params = init_params(cfg, jax.random.PRNGKey(0))
pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16, seed=7))
step_fn, opt_init = make_train_step(cfg, RunConfig(learning_rate=3e-3, warmup_steps=10))
jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
opt = opt_init(params)
for s in range(args.train_steps):
    batch = {k: jax.numpy.asarray(v) for k, v in pipe.batch_at(s).items()}
    params, opt, m = jit_step(params, opt, batch, s)
    if s % 20 == 0:
        print(f"  warmup-train step {s}: loss {float(m['loss']):.3f}")

engine = Engine(cfg, params, batch_size=4, max_seq=96, eos_id=-1, sample="greedy")
prompts = [pipe.batch_at(1000 + i)["tokens"][0, :16] for i in range(4)]
reqs = [Request(np.asarray(p, np.int32), max_new_tokens=8 + 4 * i) for i, p in enumerate(prompts)]

t0 = time.time()
out = engine.generate(reqs)
dt = time.time() - t0
n_tok = sum(len(r.out_tokens) for r in out)
print(f"\nserved {len(out)} requests, {n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
for i, r in enumerate(out):
    print(f"  req{i}: prompt {list(np.asarray(prompts[i])[:6])}... -> {r.out_tokens}")
