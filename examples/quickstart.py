"""Quickstart: optimize one CMVM with da4ml and inspect everything.

    PYTHONPATH=src python examples/quickstart.py

Covers the full core API: solve, verify bit-exactness, compare against
the hls4ml latency-strategy baseline, execute through the Pallas adder-
graph kernel, pipeline, and emit synthesizable Verilog.
"""

import numpy as np

from repro.core import (
    emit_verilog,
    naive_adder_tree,
    pipeline,
    solve_cmvm,
)
from repro.flow import SolverConfig
from repro.kernels.adder_graph import adder_graph_apply, compile_tables

# --- a random 16x16 8-bit constant matrix (paper Table 2 convention) ---
rng = np.random.default_rng(42)
M = rng.integers(2**7 + 1, 2**8, size=(16, 16))

baseline = naive_adder_tree(M)
# delay constraint: 2 extra adder levels
sol = solve_cmvm(M, config=SolverConfig(dc=2))

print(f"matrix 16x16, 8-bit  |  baseline adders: {baseline.n_adders}")
print(
    f"da4ml (dc=2): {sol.n_adders} adders "
    f"({1 - sol.n_adders / baseline.n_adders:.0%} fewer), "
    f"depth {sol.depth}, LUT-bit estimate {sol.cost_bits}, "
    f"solved in {sol.solver_time_s*1e3:.1f} ms"
)

# --- bit-exactness: the adder graph computes x @ M exactly ---
assert sol.verify(), "never happens: full numerical precision is guaranteed"
x = rng.integers(-128, 128, size=(8, 16))
np.testing.assert_array_equal(sol.evaluate(x), x @ M)
print("bit-exact vs x @ M: OK")

# --- execute through the levelized Pallas executor (TPU adaptation) ---
tables = compile_tables(sol.program)
y = adder_graph_apply(tables, x.astype(np.int32), use_pallas=True, block_b=8)
np.testing.assert_array_equal(np.asarray(y), x @ M)
print("Pallas adder-graph kernel (interpret mode): OK")

# --- pipelining + RTL ---
rep = pipeline(sol.program, max_delay_per_stage=5)
print(f"pipelined: {rep.n_stages} stages, {rep.ff_bits} FF bits, II=1")
verilog = emit_verilog(sol.program, module_name="cmvm16", max_delay_per_stage=5)
print(f"Verilog: {len(verilog.splitlines())} lines; first 3:")
print("\n".join(verilog.splitlines()[:3]))
