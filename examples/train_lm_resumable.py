"""Fault-tolerant LM training driver: train a ~small LM for a few hundred
steps with periodic async checkpoints, then kill and resume mid-run.

    PYTHONPATH=src python examples/train_lm_resumable.py
"""

import shutil
import tempfile

import jax

from repro import configs
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import init_params
from repro.train.train_lib import Trainer, make_train_step

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
cfg = configs.get_smoke("stablelm-3b", d_model=128, n_layers=4, d_ff=256)
run_cfg = RunConfig(
    learning_rate=3e-3, warmup_steps=20,
    checkpoint_every=50, checkpoint_dir=ckpt_dir, keep_checkpoints=2,
)
pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0))
step_fn, opt_init = make_train_step(cfg, run_cfg)
jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
def init_fn():
    return init_params(cfg, jax.random.PRNGKey(0))

print(f"training {cfg.name} ({cfg.param_count():,} params), ckpts -> {ckpt_dir}")
trainer = Trainer.resume_or_init(cfg, run_cfg, pipe, init_fn, jit_step, opt_init)

# phase 1: run 120 steps, then simulate a pod loss at step 90
boom = {"armed": True}
def fail_hook(step):
    if step == 90 and boom["armed"]:
        boom["armed"] = False
        raise RuntimeError("simulated: pod 1 lost heartbeat")

m = trainer.run(120, fail_hook=fail_hook)
print(f"phase 1 done at step {trainer.step}: loss {m['loss']:.3f} "
      f"(survived 1 simulated failure, resumed from checkpoint)")

# phase 2: a *new* Trainer (fresh process semantics) resumes seamlessly
trainer2 = Trainer.resume_or_init(cfg, run_cfg, pipe, init_fn, jit_step, opt_init)
assert trainer2.step == 120, trainer2.step
m = trainer2.run(80)
print(f"phase 2 (restart) done at step {trainer2.step}: loss {m['loss']:.3f}")
shutil.rmtree(ckpt_dir, ignore_errors=True)
