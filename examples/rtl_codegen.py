"""Standalone RTL generation (paper §5.2): quantized model -> Verilog,
no HLS in the loop.

    PYTHONPATH=src python examples/rtl_codegen.py
"""

import jax
import numpy as np

from repro.core import emit_verilog, pipeline, solve_cmvm
from repro.core.fixed_point import QInterval
from repro.flow import CompileConfig, SolverConfig
from repro.nn import compile_model, init_params, models

# --- single CMVM -> combinational + pipelined Verilog ---
rng = np.random.default_rng(3)
M = rng.integers(-32, 32, size=(8, 6))
sol = solve_cmvm(
    M, qint_in=[QInterval.from_fixed(True, 8, 8)] * 8, config=SolverConfig(dc=2)
)
comb = emit_verilog(sol.program, "cmvm_comb", max_delay_per_stage=None)
piped = emit_verilog(sol.program, "cmvm_piped", max_delay_per_stage=3)
print(f"combinational module: {len(comb.splitlines())} lines")
print(f"pipelined module:     {len(piped.splitlines())} lines, "
      f"{pipeline(sol.program, 3).n_stages} stages")
with open("/tmp/cmvm_piped.v", "w") as f:
    f.write(piped)
print("wrote /tmp/cmvm_piped.v")

# --- whole-network resource report through the model compiler ---
model, in_shape, in_quant = models.muon_tracker(d_in=32)
params, _ = init_params(jax.random.PRNGKey(0), model, in_shape)
design = compile_model(
    model, params, in_shape, in_quant,
    config=CompileConfig(strategy="da", solver=SolverConfig(dc=2)),
)
print("\nmuon tracker (binary inputs) DA design:")
print(design.summary())
print("\nper-layer Verilog emission of the first dense layer:")
first = solve_cmvm(
    np.round(np.asarray(params[0]["w"]) / model[0].w_quant.step).astype(np.int64),
    qint_in=[in_quant.qint] * in_shape[0],
    config=SolverConfig(dc=2),
)
v = emit_verilog(first.program, "dense0")
print("\n".join(v.splitlines()[:5]) + "\n...")
